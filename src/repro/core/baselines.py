"""Baselines the paper compares against (§VI-B): FedAvg [33], DFedAvg [15]
(momentum-free DFedAvgM, which DFedRW reduces to when all walk steps are
self-loops), and DSGD.

Like the DFedRW engine, the baselines run on the flat parameter buffer
(repro.core.flatten): device models are rows of one (n, d_pad) matrix, the
local-epoch loop is a scan of vmapped flat gradients, and QDFedAvg's
aggregation diffs (Fig. 9) quantize through the fused segment Pallas kernel
instead of a per-leaf Python loop.

All baselines *drop stragglers* (the paper's point of contrast): under h%
system heterogeneity, straggler devices neither update nor contribute to
aggregation in that round.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfedrw import DFedRWState, RoundMetrics
from repro.core.flatten import (
    flatten_tree,
    make_flat_spec,
    unflatten_tree,
)
from repro.core.graph import Topology
from repro.core.quantization import QuantConfig, wire_bits
from repro.core.walk import StragglerModel
from repro.data.synthetic import FederatedDataset
from repro.kernels.quantize import payload_quantize_dequantize
from repro.models.fnn import SmallModel
from repro.optim.sgd import decreasing_lr

__all__ = ["BaselineConfig", "FedAvg", "DFedAvg", "DSGD"]


@functools.partial(jax.jit, static_argnames=("spec", "quant"))
def _quant_agg(buf, start_buf, agg_rows, agg_w, sel_j, key, *, spec, quant):
    """Eq. 14 with quantized diffs: one fused segment-kernel call for the
    whole (S * n_agg)-message payload (QDFedAvg, Fig. 9)."""
    a, g = agg_rows.shape
    diffs = buf[agg_rows] - start_buf[agg_rows]                 # (S, n_agg, d_pad)
    deq = payload_quantize_dequantize(
        diffs.reshape(a * g, spec.d_pad),
        spec,
        per_message=True,
        bits=quant.bits,
        s=quant.s,
        key=key,
    ).reshape(a, g, spec.d_pad)
    upd = jnp.sum(agg_w[..., None] * deq, axis=1)
    return buf.at[sel_j].set(start_buf[sel_j] + upd)


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    n_selected: int = 5            # devices (or aggregators) per round
    local_epochs: int = 5          # E local SGD steps between aggregations
    batch_size: int = 50
    lr_r: float = 5.0
    lr_q: float = 0.499
    n_agg: int = 5                 # |N_A(i)| for decentralized baselines
    momentum: float = 0.0          # >0: DFedAvgM [15] -- momentum applied
                                   # during the local-epoch loop
    straggler: StragglerModel = dataclasses.field(default_factory=StragglerModel)
    quant: QuantConfig = dataclasses.field(default_factory=lambda: QuantConfig(bits=32))
    seed: int = 0


class _Base:
    def __init__(self, model: SmallModel, data: FederatedDataset, topo: Topology, cfg: BaselineConfig):
        self.model = model
        self.data = data
        self.topo = topo
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._x = jnp.asarray(data.x)
        self._y = jnp.asarray(data.y)
        self.flat_spec = make_flat_spec(
            jax.eval_shape(model.init, jax.random.PRNGKey(0))
        )
        self._loss_flat = lambda vec, batch: model.loss_fn(
            unflatten_tree(vec, self.flat_spec), batch
        )
        self._local_fn = self._build_local_fn()

    def init_state(self, key: jax.Array) -> DFedRWState:
        vec = flatten_tree(self.model.init(key), self.flat_spec)
        return DFedRWState(
            device_params=jnp.repeat(vec[None, :], self.topo.n, axis=0),
            updated=np.zeros(self.topo.n, dtype=bool),
        )

    def _build_local_fn(self):
        cfg = self.cfg
        grad_fn = jax.vmap(jax.grad(self._loss_flat))

        @jax.jit
        def local_updates(params_sel, batch_idx, kbar0):
            """params_sel: (S, d_pad); batch_idx: (S, E, B). With
            cfg.momentum > 0 this is DFedAvgM's local loop [15]."""
            x, y = self._x, self._y
            vel0 = jnp.zeros_like(params_sel)
            xb_all = jnp.swapaxes(x[batch_idx], 0, 1)   # (E, S, B, ...)
            yb_all = jnp.swapaxes(y[batch_idx], 0, 1)

            def body(carry, inputs):
                p, vel = carry
                xb, yb, step_e = inputs
                lr = decreasing_lr(kbar0 + step_e + 1, cfg.lr_r, cfg.lr_q)
                g = grad_fn(p, (xb, yb))
                vel_new = cfg.momentum * vel + g
                newp = p - lr * vel_new
                newv = jnp.where(cfg.momentum > 0, vel_new, vel)
                return (newp, newv), None

            steps = jnp.arange(batch_idx.shape[1], dtype=jnp.int32)
            (out, _), _ = jax.lax.scan(body, (params_sel, vel0),
                                       (xb_all, yb_all, steps))
            return out

        return local_updates

    def _select(self, drop_stragglers: bool = True) -> np.ndarray:
        """Baselines drop any selected persistently-slow device (it cannot
        finish E local epochs within the global clock) -- the sampling bias
        the paper criticizes. Slow devices' data is thus never trained on."""
        cfg = self.cfg
        sel = self.rng.choice(self.topo.n, size=min(cfg.n_selected, self.topo.n), replace=False)
        if drop_stragglers and cfg.straggler.h_percent > 0:
            slow = cfg.straggler.slow_mask(self.topo.n)
            sel = sel[~slow[sel]]
        return np.sort(sel)

    def _skip_round(self, state: DFedRWState) -> tuple[DFedRWState, RoundMetrics]:
        """All selected devices were stragglers: the round produces no update
        (the server/neighbors time out) -- the data-loss failure mode the
        paper attributes to (D)FedAvg."""
        new_state = dataclasses.replace(state, round=state.round + 1)
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=float("nan"),
            comm_bits_round=0.0,
            comm_bits_busiest_round=0.0,
            gamma_hat=1.0,
        )

    def _batches(self, sel: np.ndarray, epochs: int) -> np.ndarray:
        """(S, E, B) global sample indices: one rng draw + fancy indexing."""
        cfg = self.cfg
        idx_mat = self.data.client_idx                       # (n, max_size)
        cols = self.rng.integers(
            0, idx_mat.shape[1], size=(len(sel), epochs, cfg.batch_size)
        )
        return idx_mat[np.asarray(sel)[:, None, None], cols]

    def evaluate(self, state: DFedRWState, x_test, y_test, max_batch: int = 2048) -> dict:
        if state.updated is not None and state.updated.any():
            sel = jnp.asarray(np.nonzero(state.updated)[0])
        else:
            sel = jnp.arange(self.topo.n)
        mean_params = unflatten_tree(
            jnp.mean(state.device_params[sel], axis=0), self.flat_spec
        )
        x_test = jnp.asarray(x_test[:max_batch])
        y_test = jnp.asarray(y_test[:max_batch])
        logits = self.model.predict(mean_params, x_test)
        return {
            "accuracy": float(jnp.mean(jnp.argmax(logits, -1) == y_test)),
            "loss": float(self.model.loss_fn(mean_params, (x_test, y_test))),
        }

    def _mean_loss(self, params_sel, bidx_last) -> float:
        xb, yb = self._x[bidx_last], self._y[bidx_last]
        losses = jax.vmap(self._loss_flat)(params_sel, (xb, yb))
        return float(jnp.mean(losses))


class FedAvg(_Base):
    """Centralized FedAvg [33]: the server broadcasts the global model to S
    selected devices, which run E local epochs; weighted average back."""

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = self.cfg
        # Global model = row 0 (all rows kept in sync).
        sel = self._select()
        if len(sel) == 0:
            return self._skip_round(state)
        bidx = self._batches(sel, cfg.local_epochs)
        params_sel = jnp.repeat(state.device_params[:1], len(sel), axis=0)
        out = self._local_fn(params_sel, jnp.asarray(bidx), jnp.int32(state.global_step))
        sizes = self.data.client_sizes[sel].astype(np.float64)
        w = jnp.asarray((sizes / sizes.sum()).astype(np.float32))
        new_global = w @ out                                   # (d_pad,)
        new_stack = jnp.repeat(new_global[None, :], self.topo.n, axis=0)
        all_updated = np.ones(self.topo.n, dtype=bool)
        phi = wire_bits(self.flat_spec.d, cfg.quant.bits)
        tot = 2.0 * len(sel) * phi           # server <-> each selected device
        busiest = tot                         # the server is the busiest node
        new_state = DFedRWState(
            device_params=new_stack,
            round=state.round + 1,
            global_step=state.global_step + cfg.local_epochs,
            comm_bits_total=state.comm_bits_total + tot,
            comm_bits_busiest=state.comm_bits_busiest + busiest,
            updated=all_updated,
        )
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=self._mean_loss(out, bidx[:, -1]),
            comm_bits_round=tot,
            comm_bits_busiest_round=busiest,
            gamma_hat=1.0,
        )


class DFedAvg(_Base):
    """Decentralized FedAvg (DFedAvgM without momentum, [15]): every
    non-straggler device runs E local epochs on its *own* data, then
    aggregates with <= n_agg random graph neighbors (Eq. 11); optionally with
    quantized diffs (QDFedAvg, Fig. 9) through the fused segment kernel."""

    local_epochs_are_walks = False

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = self.cfg
        spec = self.flat_spec
        sel = self._select()
        if len(sel) == 0:
            return self._skip_round(state)
        bidx = self._batches(sel, cfg.local_epochs)
        sel_j = jnp.asarray(sel)
        out = self._local_fn(state.device_params[sel_j], jnp.asarray(bidx),
                             jnp.int32(state.global_step))

        # Scatter updated params back, then neighbor aggregation among sel.
        device_params = state.device_params.at[sel_j].set(out)
        sizes = self.data.client_sizes
        sel_set = set(sel.tolist())
        rows, weights = [], []
        for i in sel:
            nbrs = [j for j in self.topo.neighbors(i, include_self=True) if j in sel_set]
            self.rng.shuffle(nbrs)
            nbrs = np.array(nbrs[: cfg.n_agg], dtype=np.int64)
            pad = cfg.n_agg - len(nbrs)
            w = sizes[nbrs].astype(np.float64)
            w = w / max(w.sum(), 1.0)
            if pad > 0:
                nbrs = np.pad(nbrs, (0, pad), constant_values=i)
                w = np.pad(w, (0, pad))
            rows.append(nbrs)
            weights.append(w)
        row_mat = np.stack(rows)
        w_mat = np.stack(weights)
        agg_rows = jnp.asarray(row_mat.astype(np.int32))
        agg_w = jnp.asarray(w_mat.astype(np.float32))

        if cfg.quant.enabled:
            device_params = _quant_agg(
                device_params, state.device_params, agg_rows, agg_w, sel_j, key,
                spec=spec, quant=cfg.quant,
            )
        else:
            gathered = device_params[agg_rows]                  # (S, n_agg, d_pad)
            avg = jnp.sum(agg_w[..., None] * gathered, axis=1)
            device_params = device_params.at[sel_j].set(avg)

        phi = wire_bits(spec.d, cfg.quant.bits)
        sends = (w_mat > 0) & (row_mat != sel[:, None])
        per_dev = np.bincount(
            row_mat[sends].ravel(), minlength=self.topo.n
        ).astype(np.float64) * phi
        tot, busiest = float(per_dev.sum()), float(per_dev.max())
        updated = (state.updated.copy() if state.updated is not None
                   else np.zeros(self.topo.n, dtype=bool))
        updated[sel] = True
        new_state = DFedRWState(
            device_params=device_params,
            round=state.round + 1,
            global_step=state.global_step + cfg.local_epochs,
            comm_bits_total=state.comm_bits_total + tot,
            comm_bits_busiest=state.comm_bits_busiest + busiest,
            updated=updated,
        )
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=self._mean_loss(out, bidx[:, -1]),
            comm_bits_round=tot,
            comm_bits_busiest_round=busiest,
            gamma_hat=1.0,
        )

class DSGD(_Base):
    """Decentralized SGD: one local step then neighbor mixing, every round."""

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = dataclasses.replace(self.cfg, local_epochs=1)
        runner = DFedAvg.__new__(DFedAvg)
        runner.__dict__.update(self.__dict__)
        runner.cfg = cfg
        return DFedAvg.run_round(runner, state, key)
