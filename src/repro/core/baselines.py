"""Baselines the paper compares against (§VI-B): FedAvg [33], DFedAvg [15]
(momentum-free DFedAvgM, which DFedRW reduces to when all walk steps are
self-loops), and DSGD.

All baselines *drop stragglers* (the paper's point of contrast): under h%
system heterogeneity, straggler devices neither update nor contribute to
aggregation in that round.

Quantized DFedAvg (QDFedAvg, Fig. 9) quantizes the aggregation diffs only
(its walks are local, so there are no hand-off payloads).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfedrw import DFedRWState, RoundMetrics, _stack_params
from repro.core.graph import Topology
from repro.core.quantization import QuantConfig, dequantize, quantize, wire_bits
from repro.core.walk import StragglerModel
from repro.data.synthetic import FederatedDataset
from repro.models.fnn import SmallModel
from repro.optim.sgd import decreasing_lr

__all__ = ["BaselineConfig", "FedAvg", "DFedAvg", "DSGD"]


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    n_selected: int = 5            # devices (or aggregators) per round
    local_epochs: int = 5          # E local SGD steps between aggregations
    batch_size: int = 50
    lr_r: float = 5.0
    lr_q: float = 0.499
    n_agg: int = 5                 # |N_A(i)| for decentralized baselines
    momentum: float = 0.0          # >0: DFedAvgM [15] -- momentum applied
                                   # during the local-epoch loop
    straggler: StragglerModel = dataclasses.field(default_factory=StragglerModel)
    quant: QuantConfig = dataclasses.field(default_factory=lambda: QuantConfig(bits=32))
    seed: int = 0


class _Base:
    def __init__(self, model: SmallModel, data: FederatedDataset, topo: Topology, cfg: BaselineConfig):
        self.model = model
        self.data = data
        self.topo = topo
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._x = jnp.asarray(data.x)
        self._y = jnp.asarray(data.y)
        self._local_fn = self._build_local_fn()

    def init_state(self, key: jax.Array) -> DFedRWState:
        params = self.model.init(key)
        return DFedRWState(
            device_params=_stack_params(params, self.topo.n),
            updated=np.zeros(self.topo.n, dtype=bool),
        )

    def _build_local_fn(self):
        model = self.model
        cfg = self.cfg
        grad_fn = jax.grad(model.loss_fn)

        @jax.jit
        def local_updates(params_sel, batch_idx, kbar0):
            """params_sel: (S, ...); batch_idx: (S, E, B). With
            cfg.momentum > 0 this is DFedAvgM's local loop [15]."""
            x, y = self._x, self._y
            vel0 = jax.tree_util.tree_map(jnp.zeros_like, params_sel)

            def body(carry, inputs):
                p, vel = carry
                bidx_e, step_e = inputs
                lr = decreasing_lr(kbar0 + step_e + 1, cfg.lr_r, cfg.lr_q)
                xb, yb = x[bidx_e], y[bidx_e]  # (S, B, ...)

                def one(pp, vv, xx, yy):
                    g = grad_fn(pp, (xx, yy))
                    vv = jax.tree_util.tree_map(
                        lambda v, gg: cfg.momentum * v + gg, vv, g)
                    return jax.tree_util.tree_map(lambda a, b: a - lr * b, pp, vv)

                newp = jax.vmap(one)(p, vel, xb, yb)
                newv = jax.tree_util.tree_map(
                    lambda np_, op, v: jnp.where(cfg.momentum > 0, (op - np_) / jnp.maximum(lr, 1e-12), v),
                    newp, p, vel)
                return (newp, newv), None

            steps = jnp.arange(batch_idx.shape[1], dtype=jnp.int32)
            (out, _), _ = jax.lax.scan(body, (params_sel, vel0),
                                       (jnp.swapaxes(batch_idx, 0, 1), steps))
            return out

        return local_updates

    def _select(self, drop_stragglers: bool = True) -> np.ndarray:
        """Baselines drop any selected persistently-slow device (it cannot
        finish E local epochs within the global clock) -- the sampling bias
        the paper criticizes. Slow devices' data is thus never trained on."""
        cfg = self.cfg
        sel = self.rng.choice(self.topo.n, size=min(cfg.n_selected, self.topo.n), replace=False)
        if drop_stragglers and cfg.straggler.h_percent > 0:
            slow = cfg.straggler.slow_mask(self.topo.n)
            sel = sel[~slow[sel]]
        return np.sort(sel)

    def _skip_round(self, state: DFedRWState) -> tuple[DFedRWState, RoundMetrics]:
        """All selected devices were stragglers: the round produces no update
        (the server/neighbors time out) -- the data-loss failure mode the
        paper attributes to (D)FedAvg."""
        new_state = dataclasses.replace(state, round=state.round + 1)
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=float("nan"),
            comm_bits_round=0.0,
            comm_bits_busiest_round=0.0,
            gamma_hat=1.0,
        )

    def _batches(self, sel: np.ndarray, epochs: int) -> np.ndarray:
        cfg = self.cfg
        bidx = np.zeros((len(sel), epochs, cfg.batch_size), dtype=np.int64)
        for si, dev in enumerate(sel):
            row = self.data.client_idx[dev]
            for e in range(epochs):
                bidx[si, e] = row[self.rng.integers(0, row.shape[0], size=cfg.batch_size)]
        return bidx

    def evaluate(self, state: DFedRWState, x_test, y_test, max_batch: int = 2048) -> dict:
        if state.updated is not None and state.updated.any():
            sel = jnp.asarray(np.nonzero(state.updated)[0])
            mean_params = jax.tree_util.tree_map(lambda p: jnp.mean(p[sel], axis=0), state.device_params)
        else:
            mean_params = jax.tree_util.tree_map(lambda p: jnp.mean(p, axis=0), state.device_params)
        x_test = jnp.asarray(x_test[:max_batch])
        y_test = jnp.asarray(y_test[:max_batch])
        logits = self.model.predict(mean_params, x_test)
        return {
            "accuracy": float(jnp.mean(jnp.argmax(logits, -1) == y_test)),
            "loss": float(self.model.loss_fn(mean_params, (x_test, y_test))),
        }

    def _mean_loss(self, params_sel, bidx_last) -> float:
        xb, yb = self._x[bidx_last], self._y[bidx_last]
        losses = jax.vmap(self.model.loss_fn)(params_sel, (xb, yb))
        return float(jnp.mean(losses))


class FedAvg(_Base):
    """Centralized FedAvg [33]: the server broadcasts the global model to S
    selected devices, which run E local epochs; weighted average back."""

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = self.cfg
        # Global model = row 0 (all rows kept in sync).
        global_params = jax.tree_util.tree_map(lambda p: p[0], state.device_params)
        sel = self._select()
        if len(sel) == 0:
            return self._skip_round(state)
        bidx = self._batches(sel, cfg.local_epochs)
        params_sel = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (len(sel), *p.shape)), global_params
        )
        out = self._local_fn(params_sel, jnp.asarray(bidx), jnp.int32(state.global_step))
        sizes = self.data.client_sizes[sel].astype(np.float64)
        w = jnp.asarray((sizes / sizes.sum()).astype(np.float32))
        new_global = jax.tree_util.tree_map(
            lambda p: jnp.tensordot(w, p, axes=1), out
        )
        new_stack = _stack_params(new_global, self.topo.n)
        all_updated = np.ones(self.topo.n, dtype=bool)
        d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(new_global))
        phi = wire_bits(d, cfg.quant.bits)
        tot = 2.0 * len(sel) * phi           # server <-> each selected device
        busiest = tot                         # the server is the busiest node
        new_state = DFedRWState(
            device_params=new_stack,
            round=state.round + 1,
            global_step=state.global_step + cfg.local_epochs,
            comm_bits_total=state.comm_bits_total + tot,
            comm_bits_busiest=state.comm_bits_busiest + busiest,
            updated=all_updated,
        )
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=self._mean_loss(out, bidx[:, -1]),
            comm_bits_round=tot,
            comm_bits_busiest_round=busiest,
            gamma_hat=1.0,
        )


class DFedAvg(_Base):
    """Decentralized FedAvg (DFedAvgM without momentum, [15]): every
    non-straggler device runs E local epochs on its *own* data, then
    aggregates with <= n_agg random graph neighbors (Eq. 11); optionally with
    quantized diffs (QDFedAvg, Fig. 9)."""

    local_epochs_are_walks = False

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = self.cfg
        sel = self._select()
        if len(sel) == 0:
            return self._skip_round(state)
        bidx = self._batches(sel, cfg.local_epochs)
        params_sel = jax.tree_util.tree_map(lambda p: p[jnp.asarray(sel)], state.device_params)
        out = self._local_fn(params_sel, jnp.asarray(bidx), jnp.int32(state.global_step))

        # Scatter updated params back, then neighbor aggregation among sel.
        device_params = jax.tree_util.tree_map(
            lambda buf, upd: buf.at[jnp.asarray(sel)].set(upd), state.device_params, out
        )
        sizes = self.data.client_sizes
        sel_set = set(sel.tolist())
        rows, weights = [], []
        for i in sel:
            nbrs = [j for j in self.topo.neighbors(i, include_self=True) if j in sel_set]
            self.rng.shuffle(nbrs)
            nbrs = np.array(nbrs[: cfg.n_agg], dtype=np.int64)
            pad = cfg.n_agg - len(nbrs)
            w = sizes[nbrs].astype(np.float64)
            w = w / max(w.sum(), 1.0)
            if pad > 0:
                nbrs = np.pad(nbrs, (0, pad), constant_values=i)
                w = np.pad(w, (0, pad))
            rows.append(nbrs)
            weights.append(w)
        agg_rows = jnp.asarray(np.stack(rows).astype(np.int32))
        agg_w = jnp.asarray(np.stack(weights).astype(np.float32))
        sel_j = jnp.asarray(sel)

        if cfg.quant.enabled:
            def agg_leaf(buf, start_buf, leaf_key):
                diffs = buf[agg_rows] - start_buf[agg_rows]
                flat = diffs.reshape((-1,) + diffs.shape[2:])
                keys = jax.random.split(leaf_key, flat.shape[0])
                qd = jax.vmap(lambda dd, kk: dequantize(quantize(dd, cfg.quant, kk)))(
                    flat, keys
                ).reshape(diffs.shape)
                w = agg_w.reshape(agg_w.shape + (1,) * (diffs.ndim - 2))
                upd = jnp.sum(w * qd, axis=1)
                return buf.at[sel_j].set(start_buf[sel_j] + upd)

            leaves_last, treedef = jax.tree_util.tree_flatten(device_params)
            leaves_start = jax.tree_util.tree_leaves(state.device_params)
            keys = jax.random.split(key, len(leaves_last))
            new_leaves = [agg_leaf(a, b, kk) for a, b, kk in zip(leaves_last, leaves_start, keys)]
            device_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
        else:
            def agg_leaf(buf):
                gathered = buf[agg_rows]
                w = agg_w.reshape(agg_w.shape + (1,) * (gathered.ndim - 2))
                return buf.at[sel_j].set(jnp.sum(w * gathered, axis=1))

            device_params = jax.tree_util.tree_map(agg_leaf, device_params)

        d = sum(int(np.prod(l.shape[1:])) for l in jax.tree_util.tree_leaves(device_params))
        phi = wire_bits(d, cfg.quant.bits)
        per_dev = np.zeros(self.topo.n)
        for r, i in enumerate(sel):
            for j, w in zip(rows[r], weights[r]):
                if w > 0 and j != i:
                    per_dev[j] += phi
        tot, busiest = float(per_dev.sum()), float(per_dev.max())
        updated = (state.updated.copy() if state.updated is not None
                   else np.zeros(self.topo.n, dtype=bool))
        updated[sel] = True
        new_state = DFedRWState(
            device_params=device_params,
            round=state.round + 1,
            global_step=state.global_step + cfg.local_epochs,
            comm_bits_total=state.comm_bits_total + tot,
            comm_bits_busiest=state.comm_bits_busiest + busiest,
            updated=updated,
        )
        return new_state, RoundMetrics(
            round=new_state.round,
            train_loss=self._mean_loss(out, bidx[:, -1]),
            comm_bits_round=tot,
            comm_bits_busiest_round=busiest,
            gamma_hat=1.0,
        )


class DSGD(_Base):
    """Decentralized SGD: one local step then neighbor mixing, every round."""

    def run_round(self, state: DFedRWState, key: jax.Array) -> tuple[DFedRWState, RoundMetrics]:
        cfg = dataclasses.replace(self.cfg, local_epochs=1)
        runner = DFedAvg.__new__(DFedAvg)
        runner.__dict__.update(self.__dict__)
        runner.cfg = cfg
        return DFedAvg.run_round(runner, state, key)
