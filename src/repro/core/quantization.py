"""Stochastic quantization for communication (paper §IV-B, Eq. 12, Lemma 3).

Quantizes the *normalized* components |w_v|/||w|| onto the grid
{0, s, 2s, ..., (2^{b-1}-1) s} by unbiased stochastic rounding; one bit of b
is the sign. The wire format for a d-vector is (Lambda, s, ||w||):
b*d bits of indices+signs plus 32+32 bits of side information, i.e.
(64 + b*d) bits versus 32*d unquantized (paper's cost accounting).

QDFedRW quantizes parameter *differences* (Eq. 13/14), never raw weights,
to avoid error accumulation in non-smooth nets; callers pass diffs.

Segment wire format (flat-buffer engine)
----------------------------------------
The flat round engine (repro.core.dfedrw, engine="flat") ships a whole
payload of models as one (B, d_pad) matrix in which every model-pytree leaf
owns a 128-aligned column block (repro.core.flatten.FlatSpec). On the wire
this is a sequence of per-leaf SEGMENTS, each an independent Eq. 12 tensor
with its own (s, ||w_seg||) header:

  * hop hand-off (Eq. 13): one segment per leaf, spanning all B chain rows
    — exactly the seed semantics of quantizing the stacked (B, ...) leaf as
    one tensor. Wire cost per hand-off: sum_l (64 + b*d_l) bits.
  * aggregation (Eq. 14): one segment per (message row, leaf) — each
    neighbor's diff quantizes its leaves separately, matching the seed's
    per-row vmapped quantize. The flat engine quantizes each *sender's*
    message once and broadcasts it to every aggregator listing the sender
    (one wire message per updated device); Eq. 18 accounting still charges
    every (sender -> aggregator) edge.

  Padding lanes inside a segment hold exact zeros end to end: they quantize
  to index 0 and never contribute to norms, so d in the cost accounting is
  the TRUE parameter count (FlatSpec.d), not d_pad.

Per segment the adaptive interval is s = max_v |w_v| / (||w_seg|| * levels)
(see QuantConfig); `repro.kernels.quantize.payload_quantize_dequantize` runs
the whole payload's quantize -> dequantize round trip as one fused Pallas
kernel call with per-row (s, norm) operands.

This module is the pure-jnp reference implementation; the Pallas TPU kernel
in repro/kernels/quantize/ is bit-compatible (same grid, same rounding
given the same uniforms) and is validated against `quantize`/`dequantize`
below (tests/test_kernels_quantize.py). The flat engine's kernel draws its
stochastic-rounding uniforms from an in-register counter hash instead of
the threefry stream (statistically equivalent, ~10x cheaper on CPU), so
QDFedRW trajectories of the two engines agree to quantization noise rather
than bit-for-bit; see tests/test_flat_engine.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "Quantized",
    "SUPPORTED_WIRE_WIDTHS",
    "validate_wire_bits",
    "quantize",
    "dequantize",
    "quantize_pytree",
    "dequantize_pytree",
    "wire_bits",
    "pytree_wire_bits",
]

# Bit-widths the Eq. 12 wire format (and the fused Pallas qdq kernels, whose
# signed index must fit int8) can carry; 32 is the fp32 pass-through. The
# adaptive controller (repro.sim.adapt) and the engine's per-width program
# table validate against this set.
SUPPORTED_WIRE_WIDTHS = (2, 3, 4, 5, 6, 7, 8, 32)


def validate_wire_bits(bits: int) -> int:
    """Reject widths the wire format cannot carry (sign + index must fit the
    kernels' int8 lanes; 32 means "no quantization")."""
    if bits not in SUPPORTED_WIRE_WIDTHS:
        raise ValueError(
            f"unsupported wire bit-width {bits!r}; "
            f"supported: {SUPPORTED_WIRE_WIDTHS}")
    return int(bits)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """b-bit stochastic quantization with interval s (Eq. 12).

    bits=32 means 'no quantization' (identity; wire cost 32d).
    s=None (default) uses an ADAPTIVE per-tensor interval
    s = max_v |w_v|/||w|| / levels, so the grid spans the payload's actual
    dynamic range instead of [0, 1] (normalized components are ~1/sqrt(d);
    a fixed unit-range grid would waste ~all of its levels). The paper's
    wire format transmits s per payload (32 bits, §IV-B), which is exactly
    what makes the per-tensor choice free.
    """

    bits: int = 8
    s: float | None = None

    @property
    def levels(self) -> int:
        return (1 << (self.bits - 1)) - 1  # sign bit reserved

    @property
    def interval(self) -> float:
        """Static fallback interval (used when s is fixed)."""
        return self.s if self.s is not None else 1.0 / max(self.levels, 1)

    @property
    def enabled(self) -> bool:
        return self.bits < 32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """Wire representation of one quantized tensor: (Lambda, s, ||w||)."""

    indices: jax.Array  # int32 signed index: sgn(w_v) * ell'
    s: jax.Array        # scalar quantization interval (f32)
    norm: jax.Array     # scalar ||w|| (f32)
    shape: tuple = dataclasses.field(default=())

    def tree_flatten(self):
        return (self.indices, self.s, self.norm), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux)


def quantize(w: jax.Array, cfg: QuantConfig, key: jax.Array) -> Quantized:
    """Eq. 12: unbiased stochastic rounding of |w_v|/||w|| onto the s-grid."""
    wf = w.astype(jnp.float32)
    norm = jnp.linalg.norm(wf.reshape(-1))
    safe_norm = jnp.where(norm > 0, norm, 1.0)
    if cfg.s is None:
        # Adaptive per-tensor grid: cover [0, max|w_v|/||w||] exactly.
        xmax = jnp.max(jnp.abs(wf)) / safe_norm
        s = jnp.where(xmax > 0, xmax / max(cfg.levels, 1), 1.0).astype(jnp.float32)
    else:
        s = jnp.float32(cfg.s)
    x = jnp.abs(wf) / safe_norm          # in [0, 1]
    ell = jnp.floor(x / s)               # lower grid index
    phi = x / s - ell                    # relative position in the interval
    u = jax.random.uniform(key, wf.shape, dtype=jnp.float32)
    up = (u < phi).astype(jnp.float32)   # round up w.p. phi  (unbiased)
    idx = jnp.clip(ell + up, 0, cfg.levels).astype(jnp.int32)
    signed = idx * jnp.sign(wf).astype(jnp.int32)
    return Quantized(indices=signed, s=s, norm=norm, shape=tuple(w.shape))


def dequantize(q: Quantized, dtype: Any = jnp.float32) -> jax.Array:
    w = q.indices.astype(jnp.float32) * q.s * q.norm
    return w.astype(dtype).reshape(q.shape)


def quantize_pytree(tree, cfg: QuantConfig, key: jax.Array):
    """Quantize every leaf with an independent fold_in'd key."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    qleaves = [quantize(leaf, cfg, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, qleaves)


def dequantize_pytree(qtree, dtype: Any = jnp.float32):
    return jax.tree_util.tree_map(
        lambda q: dequantize(q, dtype),
        qtree,
        is_leaf=lambda x: isinstance(x, Quantized),
    )


def wire_bits(d: int, bits: int) -> int:
    """Paper §IV-B: quantized vector costs 64 + b*d bits; fp32 costs 32*d."""
    if bits >= 32:
        return 32 * d
    return 64 + bits * d


def pytree_wire_bits(tree, bits: int) -> int:
    sizes = [int(x.size) for x in jax.tree_util.tree_leaves(tree)]
    return sum(wire_bits(d, bits) for d in sizes)
