"""Shared experiment loop + latency model (paper Table IV) + history records."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

__all__ = ["History", "train_loop", "latency_fedavg", "latency_dfedrw"]


@dataclasses.dataclass
class History:
    rounds: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    test_accuracy: list = dataclasses.field(default_factory=list)
    test_loss: list = dataclasses.field(default_factory=list)
    comm_bits: list = dataclasses.field(default_factory=list)
    comm_bits_busiest: list = dataclasses.field(default_factory=list)
    gamma_hat: list = dataclasses.field(default_factory=list)

    def record(self, metrics, evald: dict, state) -> None:
        self.rounds.append(metrics.round)
        self.train_loss.append(metrics.train_loss)
        self.test_accuracy.append(evald["accuracy"])
        self.test_loss.append(evald["loss"])
        self.comm_bits.append(state.comm_bits_total)
        self.comm_bits_busiest.append(state.comm_bits_busiest)
        self.gamma_hat.append(metrics.gamma_hat)

    def final(self) -> dict:
        return {
            "rounds": self.rounds[-1] if self.rounds else 0,
            "accuracy": self.test_accuracy[-1] if self.test_accuracy else 0.0,
            "best_accuracy": max(self.test_accuracy, default=0.0),
            "comm_mb_busiest": (self.comm_bits_busiest[-1] / 8e6) if self.comm_bits_busiest else 0.0,
        }


def train_loop(
    runner: Any,
    rounds: int,
    x_test: np.ndarray,
    y_test: np.ndarray,
    seed: int = 0,
    eval_every: int = 1,
    callback: Callable | None = None,
) -> History:
    key = jax.random.PRNGKey(seed)
    state = runner.init_state(key)
    hist = History()
    for r in range(rounds):
        key, sub = jax.random.split(key)
        state, metrics = runner.run_round(state, sub)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            evald = runner.evaluate(state, x_test, y_test)
            hist.record(metrics, evald, state)
            if callback is not None:
                callback(r, metrics, evald)
    return hist


def latency_fedavg(k_epochs: int, t_p: float, t_c: float) -> float:
    """Table IV: T_A = K*T_p + 2*T_c per round."""
    return k_epochs * t_p + 2.0 * t_c


def latency_dfedrw(k_epochs: int, t_p: float, t_c: float) -> float:
    """Table IV: T_R = K*T_p + (K+1)*T_c per round (walk hand-offs serialize)."""
    return k_epochs * t_p + (k_epochs + 1.0) * t_c
