"""Communication graphs for decentralized FL (paper §III-A, §III-D).

Implements the undirected device graph G = (V, E) with self-loops, the
Metropolis-Hastings transition matrix (Eq. 7), its spectral quantity
lambda_P (Definition 4), and the mixing-time bound (Lemma 2).

Topologies mirror §VI-C: complete, ring, and c-regular expander graphs.
All matrices are plain numpy (host-side protocol state); only the sampled
walk indices enter jitted computation.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = [
    "Topology",
    "complete_graph",
    "ring_graph",
    "expander_graph",
    "star_graph",
    "erdos_renyi_graph",
    "is_connected",
    "metropolis_hastings_matrix",
    "lambda_p",
    "mixing_time",
    "make_topology",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A device communication graph plus its random-walk transition matrix."""

    name: str
    adjacency: np.ndarray          # (n, n) bool, symmetric, self-loops on diag
    transition: np.ndarray         # (n, n) MH transition matrix P (Eq. 7)
    lambda_p: float                # Definition 4
    n: int

    def neighbors(self, i: int, include_self: bool = False) -> np.ndarray:
        row = self.adjacency[i].copy()
        if not include_self:
            row[i] = False
        return np.nonzero(row)[0]

    def degree(self, i: int) -> int:
        # Degree excludes the self-loop, matching deg(i) in Eq. 7.
        return int(self.adjacency[i].sum()) - 1

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1) - 1


def _with_self_loops(adj: np.ndarray) -> np.ndarray:
    adj = adj.astype(bool)
    adj |= adj.T
    np.fill_diagonal(adj, True)
    return adj


def complete_graph(n: int) -> np.ndarray:
    return _with_self_loops(np.ones((n, n), dtype=bool))


def ring_graph(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = True
    adj[idx, (idx - 1) % n] = True
    return _with_self_loops(adj)


def expander_graph(n: int, c: int, seed: int = 0) -> np.ndarray:
    """c-regular expander built from c/2 random circulant shifts (c even) or
    union of random perfect matchings (c odd), per [42]'s construction style.

    Deterministic given (n, c, seed)."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    # Start from a ring to guarantee connectivity, then add random shifts.
    adj[idx, (idx + 1) % n] = True
    shifts_needed = max(0, (c - 2 + 1) // 2)
    used = {1, n - 1}
    for _ in range(shifts_needed):
        choices = [s for s in range(2, n - 1) if s not in used]
        if not choices:
            break
        s = int(rng.choice(choices))
        used.add(s)
        used.add(n - s)
        adj[idx, (idx + s) % n] = True
    return _with_self_loops(adj)


def star_graph(n: int) -> np.ndarray:
    """Centralized topology (FedAvg's implicit graph) — for baselines."""
    adj = np.zeros((n, n), dtype=bool)
    adj[0, :] = True
    adj[:, 0] = True
    return _with_self_loops(adj)


def is_connected(adjacency: np.ndarray) -> bool:
    """True iff the graph has one component (self-loops/direction ignored)."""
    adj = adjacency.astype(bool)
    adj |= adj.T
    reach = np.zeros(adj.shape[0], dtype=bool)
    reach[0] = True
    while True:
        new = reach | (adj @ reach)
        if (new == reach).all():
            return bool(reach.all())
        reach = new


def erdos_renyi_graph(n: int, p: float, seed: int = 0, max_tries: int = 200) -> np.ndarray:
    """True G(n, p) draw, resampled until connected.

    A disconnected draw has a second unit-magnitude eigenvalue, so
    lambda_P = 1 (Definition 4) and the MH walk never mixes across
    components — rejection sampling keeps the graph a genuine ER draw
    *conditioned on connectivity* instead of silently grafting a ring
    backbone onto it. Deterministic given (n, p, seed); raises when no
    connected draw appears within ``max_tries`` (p below the ~ln(n)/n
    connectivity threshold)."""
    for t in range(max_tries):
        rng = np.random.default_rng([seed, t])
        adj = _with_self_loops(np.triu(rng.random((n, n)) < p, 1))
        if is_connected(adj):
            return adj
    raise ValueError(
        f"no connected G(n={n}, p={p}) draw in {max_tries} tries; "
        f"p is likely below the ln(n)/n ~ {np.log(max(n, 2)) / max(n, 1):.3f} "
        "connectivity threshold"
    )


def metropolis_hastings_matrix(adjacency: np.ndarray, lazy: float = 0.1) -> np.ndarray:
    """Eq. 7: MH transition matrix with acceptance a(i,j)=min{1, deg(i)/deg(j)}.

    Candidate j is proposed uniformly among deg(i) neighbors; acceptance is
    min{1, deg(i)/deg(j)}, i.e. P(i,j) = min{1/deg(i), 1/deg(j)} for j != i,
    which makes P symmetric and doubly stochastic => uniform stationary
    distribution pi* = 1/n (the paper's target).

    `lazy` mixes in an identity component P <- (1-lazy) P + lazy I. Pure MH
    on an even ring is periodic (|lambda_n| = 1), violating the paper's
    Assumption 3 (aperiodicity); the graph's self-loops (paper §III-A
    "devices allow self-loops") realize exactly this laziness."""
    adj = adjacency.astype(bool)
    n = adj.shape[0]
    deg = adj.sum(axis=1) - 1  # exclude self-loop
    P = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        nbrs = nbrs[nbrs != i]
        for j in nbrs:
            P[i, j] = min(1.0 / max(deg[i], 1), 1.0 / max(deg[j], 1))
        P[i, i] = 1.0 - P[i].sum()
    if lazy > 0.0:
        P = (1.0 - lazy) * P + lazy * np.eye(n)
    assert np.all(P >= -1e-12), "MH matrix has negative entries"
    assert np.allclose(P.sum(axis=1), 1.0), "MH matrix rows must sum to 1"
    return P


def lambda_p(P: np.ndarray) -> float:
    """Definition 4: lambda_P = (max{|lambda_2|, |lambda_n|} + 1) / 2."""
    eigs = np.linalg.eigvals(P)
    eigs = np.sort(np.abs(eigs))[::-1]
    # eigs[0] ~ 1 (Perron); second largest magnitude drives mixing.
    second = eigs[1] if len(eigs) > 1 else 0.0
    return float((second + 1.0) / 2.0)


def mixing_time(P: np.ndarray, zeta: float = 1.0, eps: float = 1e-2) -> int:
    """Smallest tau with zeta * lambda_P^tau <= eps (Lemma 2 bound)."""
    lp = lambda_p(P)
    if lp <= 0.0:
        return 1
    tau = int(np.ceil(np.log(eps / zeta) / np.log(lp)))
    return max(tau, 1)


_BUILDERS = {
    "complete": lambda n, **kw: complete_graph(n),
    "ring": lambda n, **kw: ring_graph(n),
    "expander3": lambda n, **kw: expander_graph(n, 3, seed=kw.get("seed", 0)),
    "expander5": lambda n, **kw: expander_graph(n, 5, seed=kw.get("seed", 0)),
    "star": lambda n, **kw: star_graph(n),
    "erdos_renyi": lambda n, **kw: erdos_renyi_graph(
        n, kw.get("p", 0.3), seed=kw.get("seed", 0)
    ),
}


def make_topology(name: str, n: int, **kwargs) -> Topology:
    if name not in _BUILDERS:
        raise ValueError(f"unknown topology {name!r}; have {sorted(_BUILDERS)}")
    adj = _BUILDERS[name](n, **kwargs)
    P = metropolis_hastings_matrix(adj)
    return Topology(name=name, adjacency=adj, transition=P, lambda_p=lambda_p(P), n=n)
