"""Communication graphs for decentralized FL (paper §III-A, §III-D).

Implements the undirected device graph G = (V, E) with self-loops, the
Metropolis-Hastings transition matrix (Eq. 7), its spectral quantity
lambda_P (Definition 4), and the mixing-time bound (Lemma 2).

Topologies mirror §VI-C: complete, ring, and c-regular expander graphs.
All matrices are plain numpy (host-side protocol state); only the sampled
walk indices enter jitted computation.

Two representations, one protocol
---------------------------------
:class:`Topology` is the dense representation (adjacency + materialized P):
exact spectra, exact inverse-CDF walk sampling, honest up to a few thousand
devices. :class:`SparseTopology` is the fleet-scale representation for
n up to 10^6: CSR neighbor lists only, with the Eq. 7 MH kernel realized
*generatively* — propose a uniform neighbor, accept with probability
min{1, deg(i)/deg(j)}, mix in the lazy self-loop — so P(i, j) =
(1 - lazy) * min{1/deg(i), 1/deg(j)} without ever allocating the n x n
matrix. ``lambda_p``/``mixing_time`` refuse dense eigendecompositions above
``DENSE_EIG_LIMIT`` and point at the matrix-free power-iteration fallback
(:func:`lambda_p_power`, also available via ``mixing_time(method="power")``
and :meth:`SparseTopology.lambda_p_estimate`).
"""
from __future__ import annotations

import dataclasses
import functools
import numpy as np

__all__ = [
    "Topology",
    "SparseTopology",
    "complete_graph",
    "ring_graph",
    "expander_graph",
    "star_graph",
    "erdos_renyi_graph",
    "is_connected",
    "metropolis_hastings_matrix",
    "lambda_p",
    "lambda_p_power",
    "mixing_time",
    "make_topology",
    "make_sparse_topology",
    "DENSE_EIG_LIMIT",
]

# Above this many devices a dense eigendecomposition / n x n matrix is an
# O(n^2)-memory, O(n^3)-time trap: lambda_p/mixing_time raise and name the
# power-iteration fallback instead of silently allocating.
DENSE_EIG_LIMIT = 2048


@dataclasses.dataclass(frozen=True)
class Topology:
    """A device communication graph plus its random-walk transition matrix."""

    name: str
    adjacency: np.ndarray          # (n, n) bool, symmetric, self-loops on diag
    transition: np.ndarray         # (n, n) MH transition matrix P (Eq. 7)
    lambda_p: float                # Definition 4
    n: int

    @functools.cached_property
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """CSR neighbor lists (indptr, indices), self-loops EXCLUDED — built
        once and reused by every planning hot path (``neighbors`` used to
        re-scan an n-entry adjacency row per call)."""
        adj = self.adjacency.copy()
        np.fill_diagonal(adj, False)
        rows, cols = np.nonzero(adj)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(rows, minlength=self.n), out=indptr[1:])
        return indptr, cols

    @functools.cached_property
    def csr_with_self(self) -> tuple[np.ndarray, np.ndarray]:
        """CSR over adjacency rows *including* the diagonal self-loop."""
        rows, cols = np.nonzero(self.adjacency)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(rows, minlength=self.n), out=indptr[1:])
        return indptr, cols

    @functools.cached_property
    def transition_cdf(self) -> np.ndarray:
        """Row-wise CDF of P, cached for the inverse-CDF walk sampler
        (identical values to the per-call ``np.cumsum`` it replaces)."""
        return np.cumsum(self.transition, axis=1)

    def neighbors(self, i: int, include_self: bool = False) -> np.ndarray:
        indptr, indices = self.csr_with_self if include_self else self.csr
        return indices[indptr[i]:indptr[i + 1]].copy()

    def degree(self, i: int) -> int:
        # Degree excludes the self-loop, matching deg(i) in Eq. 7.
        return int(self.adjacency[i].sum()) - 1

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1) - 1


@dataclasses.dataclass(frozen=True)
class SparseTopology:
    """Implicit fleet-scale device graph: CSR neighbor lists, generative
    Eq. 7 MH sampling, no materialized transition matrix.

    ``indptr``/``indices`` exclude self-loops (every device implicitly has
    one, as in §III-A); ``lazy`` is the identity mixture of
    :func:`metropolis_hastings_matrix`. The realized chain kernel is

        P(i, j) = (1 - lazy) * min{1/deg(i), 1/deg(j)}   for j ~ i, j != i

    with the remaining mass on the self-loop — sampled in O(1) per step per
    chain by uniform-neighbor proposal + min{1, deg(i)/deg(j)} acceptance,
    identical in distribution to the dense matrix (tests/test_graph.py
    checks the analytic row against :func:`metropolis_hastings_matrix`).

    >>> topo = make_sparse_topology("ring", 6)
    >>> topo.degrees.tolist()
    [2, 2, 2, 2, 2, 2]
    >>> sorted(topo.neighbors(0).tolist())
    [1, 5]
    """

    name: str
    n: int
    indptr: np.ndarray             # (n+1,) int64 CSR row pointers (no self)
    indices: np.ndarray            # (nnz,) int64 neighbor ids
    lazy: float = 0.1

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def degree(self, i: int) -> int:
        return int(self.indptr[i + 1] - self.indptr[i])

    def neighbors(self, i: int, include_self: bool = False) -> np.ndarray:
        nbrs = self.indices[self.indptr[i]:self.indptr[i + 1]]
        if include_self:
            return np.sort(np.append(nbrs, i))
        return nbrs.copy()

    def sample_next(self, cur: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
        """One vectorized MH step for all chains at ``cur`` (three uniform
        draws per chain per step: lazy gate, neighbor proposal, acceptance).
        Isolated devices (degree 0) self-loop with probability 1."""
        cur = np.asarray(cur, dtype=np.int64)
        m = cur.shape[0]
        u_lazy = rng.random(m)
        u_prop = rng.random(m)
        u_acc = rng.random(m)
        deg = self.degrees
        d_cur = deg[cur]
        safe_deg = np.maximum(d_cur, 1)
        offs = np.minimum((u_prop * safe_deg).astype(np.int64), safe_deg - 1)
        prop = self.indices[self.indptr[cur] + offs]
        accept = u_acc * deg[prop] < d_cur          # u < deg(i)/deg(j)
        move = (u_lazy >= self.lazy) & (d_cur > 0) & accept
        return np.where(move, prop, cur)

    def mh_matvec(self, x: np.ndarray) -> np.ndarray:
        """y = P x for the implicit MH kernel (CSR edge weights + diagonal),
        the matrix-free operator behind :meth:`lambda_p_estimate`."""
        w, diag = self._edge_weights
        y = diag * x
        rows = np.repeat(np.arange(self.n), self.degrees)
        np.add.at(y, rows, w * x[self.indices])
        return y

    @functools.cached_property
    def _edge_weights(self) -> tuple[np.ndarray, np.ndarray]:
        deg = np.maximum(self.degrees, 1)
        rows = np.repeat(np.arange(self.n), self.degrees)
        w = (1.0 - self.lazy) * np.minimum(1.0 / deg[rows],
                                           1.0 / deg[self.indices])
        diag = 1.0 - np.bincount(rows, weights=w, minlength=self.n)
        return w, diag

    def lambda_p_estimate(self, iters: int = 300, seed: int = 0) -> float:
        """Definition 4 via matrix-free power iteration (no n x n matrix)."""
        return lambda_p_power(self.mh_matvec, n=self.n, iters=iters,
                              seed=seed)


def _with_self_loops(adj: np.ndarray) -> np.ndarray:
    adj = adj.astype(bool)
    adj |= adj.T
    np.fill_diagonal(adj, True)
    return adj


def complete_graph(n: int) -> np.ndarray:
    return _with_self_loops(np.ones((n, n), dtype=bool))


def ring_graph(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = True
    adj[idx, (idx - 1) % n] = True
    return _with_self_loops(adj)


def expander_graph(n: int, c: int, seed: int = 0) -> np.ndarray:
    """c-regular expander built from c/2 random circulant shifts (c even) or
    union of random perfect matchings (c odd), per [42]'s construction style.

    Deterministic given (n, c, seed)."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    # Start from a ring to guarantee connectivity, then add random shifts.
    adj[idx, (idx + 1) % n] = True
    shifts_needed = max(0, (c - 2 + 1) // 2)
    used = {1, n - 1}
    for _ in range(shifts_needed):
        choices = [s for s in range(2, n - 1) if s not in used]
        if not choices:
            break
        s = int(rng.choice(choices))
        used.add(s)
        used.add(n - s)
        adj[idx, (idx + s) % n] = True
    return _with_self_loops(adj)


def star_graph(n: int) -> np.ndarray:
    """Centralized topology (FedAvg's implicit graph) — for baselines."""
    adj = np.zeros((n, n), dtype=bool)
    adj[0, :] = True
    adj[:, 0] = True
    return _with_self_loops(adj)


def is_connected(adjacency: np.ndarray) -> bool:
    """True iff the graph has one component (self-loops/direction ignored)."""
    adj = adjacency.astype(bool)
    adj |= adj.T
    reach = np.zeros(adj.shape[0], dtype=bool)
    reach[0] = True
    while True:
        new = reach | (adj @ reach)
        if (new == reach).all():
            return bool(reach.all())
        reach = new


def erdos_renyi_graph(n: int, p: float, seed: int = 0, max_tries: int = 200) -> np.ndarray:
    """True G(n, p) draw, resampled until connected.

    A disconnected draw has a second unit-magnitude eigenvalue, so
    lambda_P = 1 (Definition 4) and the MH walk never mixes across
    components — rejection sampling keeps the graph a genuine ER draw
    *conditioned on connectivity* instead of silently grafting a ring
    backbone onto it. Deterministic given (n, p, seed); raises when no
    connected draw appears within ``max_tries`` (p below the ~ln(n)/n
    connectivity threshold)."""
    for t in range(max_tries):
        rng = np.random.default_rng([seed, t])
        adj = _with_self_loops(np.triu(rng.random((n, n)) < p, 1))
        if is_connected(adj):
            return adj
    raise ValueError(
        f"no connected G(n={n}, p={p}) draw in {max_tries} tries; "
        f"p is likely below the ln(n)/n ~ {np.log(max(n, 2)) / max(n, 1):.3f} "
        "connectivity threshold"
    )


def metropolis_hastings_matrix(adjacency: np.ndarray, lazy: float = 0.1) -> np.ndarray:
    """Eq. 7: MH transition matrix with acceptance a(i,j)=min{1, deg(i)/deg(j)}.

    Candidate j is proposed uniformly among deg(i) neighbors; acceptance is
    min{1, deg(i)/deg(j)}, i.e. P(i,j) = min{1/deg(i), 1/deg(j)} for j != i,
    which makes P symmetric and doubly stochastic => uniform stationary
    distribution pi* = 1/n (the paper's target).

    `lazy` mixes in an identity component P <- (1-lazy) P + lazy I. Pure MH
    on an even ring is periodic (|lambda_n| = 1), violating the paper's
    Assumption 3 (aperiodicity); the graph's self-loops (paper §III-A
    "devices allow self-loops") realize exactly this laziness."""
    adj = adjacency.astype(bool)
    n = adj.shape[0]
    deg = adj.sum(axis=1) - 1  # exclude self-loop
    P = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        nbrs = nbrs[nbrs != i]
        for j in nbrs:
            P[i, j] = min(1.0 / max(deg[i], 1), 1.0 / max(deg[j], 1))
        P[i, i] = 1.0 - P[i].sum()
    if lazy > 0.0:
        P = (1.0 - lazy) * P + lazy * np.eye(n)
    assert np.all(P >= -1e-12), "MH matrix has negative entries"
    assert np.allclose(P.sum(axis=1), 1.0), "MH matrix rows must sum to 1"
    return P


def lambda_p(P: np.ndarray, *, dense_limit: int = DENSE_EIG_LIMIT) -> float:
    """Definition 4: lambda_P = (max{|lambda_2|, |lambda_n|} + 1) / 2.

    Refuses the O(n^3) dense eigendecomposition above ``dense_limit``
    (raise the limit explicitly if you really mean it, or use
    :func:`lambda_p_power` / ``mixing_time(method="power")``)."""
    n = P.shape[0]
    if n > dense_limit:
        raise ValueError(
            f"lambda_p: dense eigendecomposition of a {n}x{n} transition "
            f"matrix exceeds dense_limit={dense_limit} (O(n^3) time, O(n^2) "
            "memory). Use lambda_p_power(...) / mixing_time(..., "
            "method='power'), or SparseTopology.lambda_p_estimate() at "
            "fleet scale."
        )
    eigs = np.linalg.eigvals(P)
    eigs = np.sort(np.abs(eigs))[::-1]
    # eigs[0] ~ 1 (Perron); second largest magnitude drives mixing.
    second = eigs[1] if len(eigs) > 1 else 0.0
    return float((second + 1.0) / 2.0)


def lambda_p_power(P, *, n: int | None = None, iters: int = 300,
                   seed: int = 0, tol: float = 1e-10) -> float:
    """Definition 4 via power iteration on the deflated operator, matrix-free.

    ``P`` is either a dense doubly-stochastic matrix or a callable
    ``x -> P @ x`` (pass ``n`` for the callable form). The uniform Perron
    vector is deflated analytically — B x = P x - mean(x) — and the
    iteration runs on B^2, whose dominant eigenvalue is
    max{|lambda_2|, |lambda_n|}^2 >= 0 regardless of the sign of lambda_n
    (a plain B-iteration oscillates when lambda_n < 0 dominates)."""
    if callable(P):
        if n is None:
            raise ValueError("lambda_p_power: pass n= with a callable operator")
        matvec = P
    else:
        n = P.shape[0]
        matvec = lambda x: P @ x
    if n < 2:
        return 0.5
    rng = np.random.default_rng([seed, 97])
    x = rng.standard_normal(n)
    x -= x.mean()
    x /= np.linalg.norm(x)
    second_sq = 0.0
    for _ in range(iters):
        y = matvec(x)
        y -= y.mean()
        y = matvec(y)
        y -= y.mean()
        norm = np.linalg.norm(y)
        if norm < 1e-300:
            second_sq = 0.0
            break
        y /= norm
        prev, second_sq = second_sq, float(norm)
        x = y
        if abs(second_sq - prev) <= tol * max(second_sq, 1.0):
            break
    second = float(np.sqrt(max(second_sq, 0.0)))
    return (min(second, 1.0) + 1.0) / 2.0


def mixing_time(P: np.ndarray, zeta: float = 1.0, eps: float = 1e-2,
                *, method: str = "dense",
                dense_limit: int = DENSE_EIG_LIMIT) -> int:
    """Smallest tau with zeta * lambda_P^tau <= eps (Lemma 2 bound).

    ``method="dense"`` uses the exact eigendecomposition and inherits the
    ``dense_limit`` guard of :func:`lambda_p`; ``method="power"`` uses the
    matrix-free estimate of :func:`lambda_p_power` at any size."""
    if method == "dense":
        lp = lambda_p(P, dense_limit=dense_limit)
    elif method == "power":
        lp = lambda_p_power(P)
    else:
        raise ValueError(f"mixing_time: unknown method {method!r} "
                         "(expected 'dense' or 'power')")
    if lp <= 0.0:
        return 1
    tau = int(np.ceil(np.log(eps / zeta) / np.log(lp)))
    return max(tau, 1)


_BUILDERS = {
    "complete": lambda n, **kw: complete_graph(n),
    "ring": lambda n, **kw: ring_graph(n),
    "expander3": lambda n, **kw: expander_graph(n, 3, seed=kw.get("seed", 0)),
    "expander5": lambda n, **kw: expander_graph(n, 5, seed=kw.get("seed", 0)),
    "star": lambda n, **kw: star_graph(n),
    "erdos_renyi": lambda n, **kw: erdos_renyi_graph(
        n, kw.get("p", 0.3), seed=kw.get("seed", 0)
    ),
}


def make_topology(name: str, n: int, **kwargs) -> Topology:
    if name not in _BUILDERS:
        raise ValueError(f"unknown topology {name!r}; have {sorted(_BUILDERS)}")
    adj = _BUILDERS[name](n, **kwargs)
    P = metropolis_hastings_matrix(adj)
    return Topology(name=name, adjacency=adj, transition=P, lambda_p=lambda_p(P), n=n)


# --------------------------------------------------------------------------
# Generative (implicit) topologies: build CSR neighbor lists directly from
# edge arrays, never touching an n x n matrix. All builders are O(n + |E|).
# --------------------------------------------------------------------------

def _csr_from_edges(n: int, src: np.ndarray, dst: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrize, dedupe, drop self-edges, and pack (src, dst) into CSR
    with each row's neighbor list sorted ascending."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    all_src = np.concatenate([src, dst])
    all_dst = np.concatenate([dst, src])
    key = all_src * n + all_dst
    uniq = np.unique(key)
    rows = uniq // n
    cols = uniq % n
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
    return indptr, cols


def _sparse_ring_edges(n: int) -> tuple[np.ndarray, np.ndarray]:
    i = np.arange(n, dtype=np.int64)
    return i, (i + 1) % n


def _sparse_expander_edges(n: int, c: int, seed: int
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Ring backbone + (c - 2) random circulant shifts: connected, near-regular,
    the same construction as the dense ``expander_graph`` recipe."""
    i = np.arange(n, dtype=np.int64)
    src = [i]
    dst = [(i + 1) % n]
    rng = np.random.default_rng([seed, 11])
    shifts: set[int] = set()
    while len(shifts) < max(c - 2, 0) and len(shifts) < max(n - 3, 0):
        s = int(rng.integers(2, n - 1))
        if s in shifts or (n - s) in shifts:
            continue
        shifts.add(s)
        src.append(i)
        dst.append((i + s) % n)
    return np.concatenate(src), np.concatenate(dst)


def _sparse_metro_edges(n: int, devices_per_cell: int, cells_per_metro: int,
                        seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Hierarchical fleet graph: per-cell ring + one random chord per device,
    cell gateways (device 0 of each cell) ringed within a metro, metro
    gateways ringed across the fleet. Max degree ~6, connected, and aligned
    with the hierarchical link model's device->cell->metro->backbone tiers."""
    dpc = max(int(devices_per_cell), 2)
    i = np.arange(n, dtype=np.int64)
    cell = i // dpc
    n_cells = int(cell[-1]) + 1 if n else 0
    src_l, dst_l = [], []
    # Intra-cell ring.
    start = cell * dpc
    size = np.minimum(start + dpc, n) - start
    nxt = start + (i - start + 1) % np.maximum(size, 1)
    keep = size > 1
    src_l.append(i[keep]); dst_l.append(nxt[keep])
    # Intra-cell random chords (skip size-<=2 cells where a chord is a dup).
    rng = np.random.default_rng([seed, 13])
    offs = rng.integers(2, np.maximum(size, 3))
    chord = start + (i - start + offs) % np.maximum(size, 1)
    keep = size > 2
    src_l.append(i[keep]); dst_l.append(chord[keep])
    # Cell-gateway ring within each metro.
    cells = np.arange(n_cells, dtype=np.int64)
    metro = cells // max(cells_per_metro, 1)
    n_metros = int(metro[-1]) + 1 if n_cells else 0
    m_start = metro * cells_per_metro
    m_size = np.minimum(m_start + cells_per_metro, n_cells) - m_start
    nxt_cell = m_start + (cells - m_start + 1) % np.maximum(m_size, 1)
    keep = m_size > 1
    src_l.append(cells[keep] * dpc); dst_l.append(nxt_cell[keep] * dpc)
    # Metro-gateway ring across the fleet.
    if n_metros > 1:
        metros = np.arange(n_metros, dtype=np.int64)
        src_l.append(metros * cells_per_metro * dpc)
        dst_l.append(((metros + 1) % n_metros) * cells_per_metro * dpc)
    return np.concatenate(src_l), np.concatenate(dst_l)


_SPARSE_BUILDERS = {
    "ring": lambda n, **kw: _sparse_ring_edges(n),
    "expander3": lambda n, **kw: _sparse_expander_edges(
        n, 3, kw.get("seed", 0)),
    "expander5": lambda n, **kw: _sparse_expander_edges(
        n, 5, kw.get("seed", 0)),
    "metro": lambda n, **kw: _sparse_metro_edges(
        n, kw.get("devices_per_cell", 100), kw.get("cells_per_metro", 32),
        kw.get("seed", 0)),
}


def make_sparse_topology(name: str, n: int, lazy: float = 0.1,
                         **kwargs) -> SparseTopology:
    """Build an implicit CSR topology without materializing any n x n array.

    Same MH chain law as ``make_topology`` (Eq. 7 with the default lazy=0.1
    identity mixture) but realized generatively; see :class:`SparseTopology`."""
    if n < 2:
        raise ValueError("make_sparse_topology: need n >= 2")
    if name not in _SPARSE_BUILDERS:
        raise ValueError(
            f"unknown sparse topology {name!r}; have {sorted(_SPARSE_BUILDERS)}")
    src, dst = _SPARSE_BUILDERS[name](n, **kwargs)
    indptr, indices = _csr_from_edges(n, src, dst)
    return SparseTopology(name=name, n=n, indptr=indptr, indices=indices,
                          lazy=lazy)
