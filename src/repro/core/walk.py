"""Random-walk trajectory machinery (paper §III-D, Alg. 1 lines 3-9).

Samples M parallel Metropolis-Hastings random-walk chains over the device
graph and models system heterogeneity as variable chain lengths K_m
(the paper's straggler-tolerant partial walks, §VI-A "system heterogeneity").

Walk sampling is host-side numpy (it is protocol state, a few ints per
round); the resulting index arrays are fed to jitted training steps.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.graph import SparseTopology, Topology

__all__ = [
    "WalkPlan",
    "ChainResume",
    "sample_walks",
    "StragglerModel",
    "gamma_inexactness",
]


@dataclasses.dataclass(frozen=True)
class ChainResume:
    """Cut-state of walk chains at an aggregation trigger.

    The fully-asynchronous simulator (repro.sim, ``policy="overlap"``) lets a
    chain span multiple aggregation triggers: when a trigger fires, the chain
    contributes the prefix of steps it completed *this window* (Eq. 11/14
    partial updates) and then keeps walking instead of being discarded. The
    runner's internal slot planner holds the full resumable state (remaining
    trajectory + batch indices + pending events); this record is the public
    summary it attaches to the executed window's :class:`WalkPlan` — the
    round records, recorded traces and tests read chain liveness, lifetime
    progress and anchors from here.

    live:   (M,) bool  — chain still in flight after the trigger (it neither
                          finished its K_m steps nor was churn-killed).
    k_done: (M,) int32 — steps completed over the chain's whole life so far.
    anchor: (M,) int32 — device whose row holds each chain's current model:
                          the device of its last completed step, i.e. the row
                          the w^{t,last} scatter wrote (a trigger therefore
                          "refreshes" a resumed chain with whatever that row
                          holds after aggregation — see repro.sim.runner).
    """

    live: np.ndarray
    k_done: np.ndarray
    anchor: np.ndarray

    @property
    def n_live(self) -> int:
        return int(self.live.sum())


@dataclasses.dataclass(frozen=True)
class WalkPlan:
    """One communication round's worth of random-walk trajectories.

    devices: (M, K_max) int32 — device visited at step k of chain m.
    mask:    (M, K_max) bool  — True where the chain performs step k. The
        synchronous planner emits *prefix* masks (chain m performs its first
        K_m <= K_max steps); the asynchronous simulator's *window views* may
        mask out column 0 — a resumed chain's leading column is its anchor
        device, a pure re-gather of the model it left there, not a step.
    k_m:     (M,) int32       — number of executed steps (= mask.sum(1)).
    last_device: (M,) int32   — device holding w^{t,last} of each chain.
    timestamps: (M, K_max) f64 | None — virtual-time completion instant of
        each hop's local step, filled in by the discrete-event simulator
        (repro.sim); NaN where the step never executed. The synchronous
        engine leaves it None.
    resume: ChainResume | None — live state of chains spanning past this
        plan's trigger (repro.sim ``policy="overlap"``); None everywhere
        else.
    """

    devices: np.ndarray
    mask: np.ndarray
    k_m: np.ndarray
    timestamps: np.ndarray | None = None
    resume: ChainResume | None = None

    @property
    def last_device(self) -> np.ndarray:
        """Device of each chain's last *executed* step (mask-general: window
        views may lead with a masked anchor column). Chains with no executed
        step fall back to their column-0 device."""
        m = self.devices.shape[0]
        any_active = self.mask.any(axis=1)
        last = self.k_max - 1 - np.argmax(self.mask[:, ::-1], axis=1)
        idx = np.where(any_active, last, 0)
        return self.devices[np.arange(m), idx]

    @property
    def m(self) -> int:
        return self.devices.shape[0]

    @property
    def k_max(self) -> int:
        return self.devices.shape[1]

    def truncated(
        self, k_new: np.ndarray, timestamps: np.ndarray | None = None
    ) -> "WalkPlan":
        """Deadline/churn truncation hook: the same trajectories, cut to
        ``min(k_m, k_new)`` completed steps per chain (k_new may be 0 — a
        chain that never finished a step contributes nothing). The truncated
        plan feeds Eq. 18 comm accounting and the Eq. 11/14 partial-update
        aggregation exactly like a straggler-shortened walk."""
        k_m = np.minimum(self.k_m, np.asarray(k_new, dtype=np.int32))
        k_m = np.maximum(k_m, 0).astype(np.int32)
        mask = np.arange(self.k_max)[None, :] < k_m[:, None]
        return WalkPlan(
            devices=self.devices,
            mask=mask,
            k_m=k_m,
            timestamps=self.timestamps if timestamps is None else timestamps,
        )


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """System heterogeneity h% (paper §III-C, §VI-A): a FIXED h% of devices
    are persistently slow (hardware/battery/network capability), with epoch
    cost `slowdown`x a fast device's. A global clock budgets each round at
    K fast-epochs; a random-walk chain stops when its cumulative cost along
    the visited devices exceeds the budget -- the paper's variable K_m
    partial walks. Baselines instead *drop* any selected slow device (it
    cannot finish E local epochs inside the clock), which is exactly the
    sampling bias the paper criticizes.

    gamma-inexactness view (Def. 2 / Lemma 1): a slow device has larger
    gamma_i, so chains through slow devices realize fewer effective updates.
    """

    h_percent: float = 0.0
    slowdown: float = 5.0
    seed: int = 1234
    mode: str = "partial"  # "partial": slow devices do 1/slowdown of the batch
                           #            within the clock (paper: "integrating
                           #            partial contributions from stragglers")
                           # "truncate": budget-based variable K_m chains

    def slow_mask(self, n: int) -> np.ndarray:
        """Deterministic fixed slow-device set."""
        n_slow = int(round(n * self.h_percent / 100.0))
        mask = np.zeros(n, dtype=bool)
        if n_slow > 0:
            rng = np.random.default_rng(self.seed)
            mask[rng.choice(n, size=n_slow, replace=False)] = True
        return mask

    def chain_lengths(self, devices: np.ndarray, k: int, n: int) -> np.ndarray:
        """K_m per chain: steps completable within a budget of k fast-epochs,
        where steps on slow devices cost `slowdown`."""
        m = devices.shape[0]
        if self.h_percent <= 0 or self.mode == "partial":
            return np.full(m, k, dtype=np.int32)
        slow = self.slow_mask(n)
        cost = np.where(slow[devices], self.slowdown, 1.0)  # (M, K)
        cum = np.cumsum(cost, axis=1)
        k_m = (cum <= float(k)).sum(axis=1).astype(np.int32)
        return np.maximum(k_m, 1)  # every chain contributes at least one step


def sample_walks(
    topo: Topology | SparseTopology,
    m: int,
    k: int,
    rng: np.random.Generator,
    straggler: StragglerModel | None = None,
    start_devices: np.ndarray | None = None,
) -> WalkPlan:
    """Sample M MH random-walk chains of (variable) length <= K.

    Start devices are uniform over V (Alg. 1 line 3) unless given (the
    large-scale LM experiment chains rounds: i_m^{t,0} = i_m^{t-1,last}).

    Accepts either representation: a dense :class:`Topology` steps by
    inverse-CDF over cached transition rows (RNG-stream-identical to the
    original per-call ``np.cumsum`` path), an implicit
    :class:`SparseTopology` steps via its generative proposal/acceptance
    kernel (same chain law, different — but deterministic — stream)."""
    if start_devices is None:
        start = rng.integers(0, topo.n, size=m)
    else:
        start = np.asarray(start_devices, dtype=np.int64) % topo.n
    devices = np.zeros((m, k), dtype=np.int32)
    n = topo.n
    cur = start.astype(np.int64)
    if getattr(topo, "transition", None) is None:
        # Implicit SparseTopology: generative MH kernel, no CDF rows to
        # gather — one vectorized proposal/acceptance step for all M chains.
        for step in range(k):
            devices[:, step] = cur
            cur = topo.sample_next(cur, rng)
    else:
        cdf = topo.transition_cdf
        # All M chains advance together: one uniform draw per step, one
        # inverse-CDF lookup on the M gathered kernel rows (vectorized
        # searchsorted: count of cdf entries <= u, which includes the
        # self-loop mass).
        for step in range(k):
            devices[:, step] = cur
            u = rng.random(m)
            cur = np.minimum((cdf[cur] <= u[:, None]).sum(axis=1), n - 1)
    k_m = (
        straggler.chain_lengths(devices, k, topo.n)
        if straggler is not None
        else np.full(m, k, dtype=np.int32)
    )
    mask = np.arange(k)[None, :] < k_m[:, None]
    return WalkPlan(devices=devices, mask=mask, k_m=k_m)


def gamma_inexactness(grad_norm_end: float, grad_norm_start: float) -> float:
    """Empirical gamma-hat of Lemma 1: ||∇F(w^k)|| / ||∇F(w^{k-K})||, the
    realized inexactness of one random-walk trajectory."""
    if grad_norm_start <= 0.0:
        return 1.0
    return float(grad_norm_end / grad_norm_start)
