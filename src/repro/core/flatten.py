"""Flat parameter-buffer codec for the vectorized DFedRW round engine.

The protocol engine keeps all n device models as ONE `(n, d_pad)` float32
matrix instead of a stacked pytree, so every protocol operation — chain
gathers, straggler masking, `w^{t,last}` scatters, Eq. 11/14 aggregation and
Eq. 12 quantization — is a single 2-D array op.

Layout: leaves are concatenated in pytree order along the last axis, each
leaf padded up to a multiple of ``LANES`` (= 128, the TPU lane width) so

  * every leaf occupies a whole number of 128-element rows, which lets the
    fused Pallas quantization kernel apply per-leaf (segment-wise) adaptive
    grids via per-row scale operands (see repro.kernels.quantize), and
  * a payload of B models reshapes to ``(B * rows_per_model, 128)`` with each
    row belonging to exactly one (model, leaf) segment.

Padding entries start at zero and stay exactly zero through the whole
protocol: gradients w.r.t. them vanish (``unflatten`` never reads them),
quantized diffs at zero are zero, and aggregation is linear.

`masked_scatter_last_wins` is the vectorized replacement for the seed
engine's per-chain ``lax.fori_loop``/``lax.cond`` scatter: it reproduces the
sequential tie-breaking semantics (the highest-index *active* chain visiting
a device in a step owns its `w^{t,last}` slot) with one scatter-max over
chain priorities plus one row scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LANES",
    "FlatSpec",
    "make_flat_spec",
    "flatten_tree",
    "unflatten_tree",
    "elect_writers",
    "masked_scatter_last_wins",
]

LANES = 128


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static codec between a model pytree and its padded flat layout.

    shapes/sizes describe the *single-model* leaves (no batch axes);
    ``offsets[l] : offsets[l] + sizes[l]`` is leaf l's live slice of the flat
    vector, inside its 128-aligned segment of ``padded_sizes[l]`` elements.
    """

    treedef: Any
    shapes: tuple
    sizes: tuple            # true element counts per leaf
    padded_sizes: tuple     # aligned up to a multiple of LANES
    offsets: tuple          # start of each leaf segment in the flat vector
    d: int                  # true total parameter count (wire accounting)
    d_pad: int              # flat vector length (multiple of LANES)

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def rows(self) -> int:
        """128-lane rows per flattened model."""
        return self.d_pad // LANES

    def row_leaf_ids(self) -> np.ndarray:
        """(rows,) int32: which leaf each 128-lane row belongs to."""
        ids = np.zeros(self.rows, dtype=np.int32)
        for l, (off, psize) in enumerate(zip(self.offsets, self.padded_sizes)):
            ids[off // LANES : (off + psize) // LANES] = l
        return ids


def make_flat_spec(template: Any) -> FlatSpec:
    """Build the codec from a single-model pytree (arrays or ShapeDtypeStructs)."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    padded = tuple(-(-sz // LANES) * LANES for sz in sizes)
    offsets = tuple(int(o) for o in np.concatenate([[0], np.cumsum(padded)[:-1]]))
    return FlatSpec(
        treedef=treedef,
        shapes=shapes,
        sizes=sizes,
        padded_sizes=padded,
        offsets=offsets,
        d=int(sum(sizes)),
        d_pad=int(sum(padded)),
    )


def flatten_tree(tree: Any, spec: FlatSpec) -> jax.Array:
    """Pack a pytree with leaves of shape ``batch_shape + spec.shapes[l]``
    into a ``batch_shape + (d_pad,)`` matrix (zero padding between leaves)."""
    leaves = jax.tree_util.tree_leaves(tree)
    bshape = leaves[0].shape[: leaves[0].ndim - len(spec.shapes[0])]
    segs = []
    for leaf, size, psize in zip(leaves, spec.sizes, spec.padded_sizes):
        flat = jnp.reshape(leaf, bshape + (size,))
        pad = [(0, 0)] * len(bshape) + [(0, psize - size)]
        segs.append(jnp.pad(flat, pad))
    return jnp.concatenate(segs, axis=-1)


def unflatten_tree(flat: jax.Array, spec: FlatSpec) -> Any:
    """Inverse of :func:`flatten_tree`; drops the padding entries."""
    bshape = flat.shape[:-1]
    leaves = []
    for shape, size, off in zip(spec.shapes, spec.sizes, spec.offsets):
        seg = jax.lax.slice_in_dim(flat, off, off + size, axis=flat.ndim - 1)
        leaves.append(jnp.reshape(seg, bshape + shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def elect_writers(
    idx: jax.Array, mask: jax.Array, n: int
) -> tuple[jax.Array, jax.Array]:
    """Elect, per target row, the LAST active writer in sequence order.

    Returns ``(winner, wins)``: ``winner[j]`` is the index of the writer that
    owns row j (-1 if untouched) and ``wins[c]`` marks writers that own their
    row. One scatter-max over writer priorities (inactive writers carry
    priority -1 and can never win); winners are unique per row by
    construction.
    """
    m = idx.shape[0]
    prio = jnp.where(mask, jnp.arange(m, dtype=jnp.int32), -1)
    winner = (
        jnp.full((n,), -1, dtype=jnp.int32)
        .at[idx]
        .max(prio, mode="drop")
    )
    wins = (winner[idx] == jnp.arange(m, dtype=jnp.int32)) & mask
    return winner, wins


def masked_scatter_last_wins(
    buf: jax.Array, idx: jax.Array, mask: jax.Array, values: jax.Array
) -> jax.Array:
    """Vectorized equivalent of the sequential masked row scatter

        for c in range(M):
            if mask[c]:
                buf = buf.at[idx[c]].set(values[c])

    i.e. among active writers that hit the same row, the highest index wins
    (`elect_writers`); a single row scatter then writes only the winners.
    Losers/inactive writers are redirected to DISTINCT out-of-bounds rows
    ``n + c`` and dropped, so every index is genuinely unique and the
    scatter can honestly carry the ``unique_indices`` fast path.
    """
    m = idx.shape[0]
    n = buf.shape[0]
    _, wins = elect_writers(idx, mask, n)
    target = jnp.where(wins, idx, n + jnp.arange(m, dtype=idx.dtype))
    return buf.at[target].set(values, mode="drop", unique_indices=True)
