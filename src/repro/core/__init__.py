"""The paper's contribution: DFedRW / QDFedRW protocol core."""
from repro.core.graph import (
    SparseTopology, Topology, make_sparse_topology, make_topology)
from repro.core.walk import WalkPlan, sample_walks, StragglerModel
from repro.core.quantization import QuantConfig, Quantized, quantize, dequantize
from repro.core.flatten import FlatSpec, flatten_tree, make_flat_spec, unflatten_tree
from repro.core.dfedrw import DFedRW, DFedRWConfig, DFedRWState
from repro.core.baselines import BaselineConfig, FedAvg, DFedAvg, DSGD
from repro.core.metrics import History, train_loop

__all__ = [
    "Topology", "make_topology", "SparseTopology", "make_sparse_topology",
    "WalkPlan", "sample_walks", "StragglerModel",
    "QuantConfig", "Quantized", "quantize", "dequantize",
    "FlatSpec", "flatten_tree", "make_flat_spec", "unflatten_tree",
    "DFedRW", "DFedRWConfig", "DFedRWState",
    "BaselineConfig", "FedAvg", "DFedAvg", "DSGD",
    "History", "train_loop",
]
