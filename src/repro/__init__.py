"""DFedRW: Decentralized Federated Averaging via Random Walk — JAX framework.

Subpackages: core (the paper's protocol), models, dist, kernels, data,
optim, checkpoint, configs, launch. See README.md / DESIGN.md.
"""
__version__ = "1.0.0"
