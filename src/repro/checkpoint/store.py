"""Flat-npz checkpointing with pytree structure preserved by key paths.

Layout: <dir>/step_<N>.npz holding one array per flattened key path plus a
__meta__ JSON blob (step, metrics, extra). Works for any param/opt pytree
in this repo (dicts/lists/tuples of arrays).
"""
from __future__ import annotations

import io
import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]

_SEP = "||"


def _flatten(tree: Any) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key or "__root__"] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any, metrics: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten(tree)
    meta = {"step": int(step), "metrics": metrics or {}}
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8),
                 **arrays)
    os.replace(tmp, path)
    return path


def load_checkpoint(directory: str, template: Any, step: int | None = None) -> tuple[Any, dict]:
    """Restore into `template`'s structure (shapes/dtypes validated)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"].tobytes()).decode())
        arrays = {k: data[k] for k in data.files if k != "__meta__"}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p) or "__root__"
        arr = arrays[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in zip(flat, leaves)]), meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None
