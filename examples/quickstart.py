"""Quickstart: DFedRW vs FedAvg on heterogeneous federated data in ~2 min.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BaselineConfig, DFedRW, DFedRWConfig, FedAvg,
                        StragglerModel, make_topology, train_loop)
from repro.core.heterogeneity import partition_similarity
from repro.data import FederatedDataset, synthetic_image_classification
from repro.models import make_fnn


def main():
    # 20 devices, fully Non-IID shards (u=0), 90% stragglers -- the paper's
    # hardest setting (Fig. 6 right columns).
    x, y = synthetic_image_classification(n_samples=6000, seed=0, noise=2.0)
    xt, yt = synthetic_image_classification(n_samples=800, seed=1, noise=2.0)
    part = partition_similarity(y, 20, u_percent=0, rng=np.random.default_rng(7))
    data = FederatedDataset.from_partition(x, y, part)
    topo = make_topology("complete", 20)
    model = make_fnn((100,))
    strag = StragglerModel(h_percent=90)

    print("== DFedRW (random-walk updates, straggler partial contributions)")
    runner = DFedRW(model, data, topo,
                    DFedRWConfig(m_chains=5, k_walk=5, straggler=strag))
    h_rw = train_loop(runner, 60, xt, yt, eval_every=15,
                      callback=lambda r, m, e: print(f"  round {r+1}: acc={e['accuracy']:.3f}"))

    print("== FedAvg (drops stragglers)")
    fed = FedAvg(model, data, topo,
                 BaselineConfig(n_selected=5, local_epochs=5, straggler=strag))
    h_fa = train_loop(fed, 60, xt, yt, eval_every=15,
                      callback=lambda r, m, e: print(f"  round {r+1}: acc={e['accuracy']:.3f}"))

    print(f"\nDFedRW  final acc: {h_rw.test_accuracy[-1]:.3f} "
          f"(busiest device: {h_rw.comm_bits_busiest[-1]/8e6:.1f} MB)")
    print(f"FedAvg  final acc: {h_fa.test_accuracy[-1]:.3f} "
          f"(busiest device: {h_fa.comm_bits_busiest[-1]/8e6:.1f} MB)")


if __name__ == "__main__":
    main()
