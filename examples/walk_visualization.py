"""Inspect the random-walk machinery: MH transition matrices, mixing times,
and straggler-adaptive chain lengths across topologies (paper Fig. 1/8).

  PYTHONPATH=src python examples/walk_visualization.py
"""
import numpy as np

from repro.core.graph import make_topology, mixing_time
from repro.core.walk import StragglerModel, sample_walks


def main():
    n = 20
    rng = np.random.default_rng(0)
    print(f"{'topology':12s} {'lambda_P':>9s} {'tau(0.01)':>9s}  (paper Def. 4 / Lemma 2)")
    for name in ["complete", "expander5", "expander3", "ring"]:
        topo = make_topology(name, n)
        print(f"{name:12s} {topo.lambda_p:9.4f} {mixing_time(topo.transition):9d}")

    topo = make_topology("expander3", n)
    strag = StragglerModel(h_percent=50, mode="truncate")
    plan = sample_walks(topo, 5, 8, rng, straggler=strag)
    slow = strag.slow_mask(n)
    print(f"\nslow devices: {np.nonzero(slow)[0].tolist()}")
    for mm in range(plan.m):
        path = " -> ".join(f"{d}{'*' if slow[d] else ''}"
                           for d in plan.devices[mm, :plan.k_m[mm]])
        print(f"chain {mm}: K_m={plan.k_m[mm]}  {path}")
    print("(* = straggler; truncate mode budgets chains by device capability)")


if __name__ == "__main__":
    main()
