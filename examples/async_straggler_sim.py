"""Fully-async DFedRW: overlapping rounds vs truncating vs dropping chains.

Runs the `overlap_async` scenario (lognormal heavy-tailed device rates with
the aggregation deadline at HALF a median chain's walk, so nearly every
chain is cut mid-flight) three times at identical protocol seeds and timing
draws:

* ``policy="overlap"`` — the fully-asynchronous mode: a cut chain
  aggregates its completed prefix AND keeps walking across windows (the
  persistent event queue carries its in-flight step/transfer; the next
  window re-anchors it on the device holding its model);
* ``policy="partial"`` — the lockstep paper baseline: the prefix
  aggregates, the rest of the walk is truncated away;
* ``policy="drop"``   — the FedAvg-style baseline: unfinished chains are
  discarded entirely (but still pay Eq. 18 for their hops).

The overlap run is captured with ``record=True`` and saved as a versioned
JSONL event trace, then replayed through the flat engine (zero event
simulation) to demonstrate the bit-exact replay contract — the same
mechanism that lets a recorded timeline drive the pod-scale gossip
deployment as an integration fixture. See docs/SIMULATOR.md.

The overlap run also carries a virtual-clock ``repro.obs`` recorder: the
telemetry stream is saved alongside the trace and rendered through the
standard run report (time-in-phase, Eq. 18 comm by width, resume/kill
counters, window-length tails). See docs/OBSERVABILITY.md.

Usage:  PYTHONPATH=src python examples/async_straggler_sim.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.obs import ObsStream, Recorder, VirtualClock, provenance, render_report
from repro.sim import SimTrace, build_scenario

N, SEED, ROUNDS = 20, 0, 24
TRACE_PATH = os.path.join(tempfile.gettempdir(),
                          "async_straggler_trace.jsonl")
OBS_PATH = os.path.join(tempfile.gettempdir(),
                        "async_straggler_obs.jsonl")


def run(name: str, record: bool = False, obs: bool = False, **overrides):
    setup = build_scenario(name, n=N, seed=SEED, rounds=ROUNDS, **overrides)
    runner = setup.runner()
    if obs:
        runner.attach_obs(Recorder(clock=VirtualClock()))
    label = f"{name}/{setup.sim.policy}"
    print(f"\n== {label}: deadline={setup.sim.deadline_s}s "
          f"bits={setup.cfg.quant.bits}")

    def cb(r, metrics, evald, rec):
        print(f"  round {rec.round:3d}  t={rec.t_end:7.1f}s  "
              f"acc={evald['accuracy']:.3f}  "
              f"truncated={rec.truncated_chains} "
              f"resumed={rec.resumed_chains} "
              f"dropped={rec.dropped_chains} "
              f"killed={int(rec.killed.sum())}")

    result = runner.run(setup.rounds, jax.random.PRNGKey(SEED),
                        setup.x_test, setup.y_test, eval_every=6,
                        callback=cb, record=record)
    final = result.final()
    finished = int(sum((r.k_done == r.k_planned).sum() for r in result.records))
    print(f"  final acc={final['accuracy']:.3f} "
          f"virtual_time={final['virtual_time_s']:.0f}s "
          f"events={final['events_total']} full_walks={finished}")
    return result, setup, runner


def main() -> None:
    overlap, setup, runner = run("overlap_async", policy="overlap",
                                 record=True, obs=True)
    partial, _, _ = run("overlap_async", policy="partial")
    drop, _, _ = run("overlap_async", policy="drop")

    a_o, a_p, a_d = (r.final()["accuracy"] for r in (overlap, partial, drop))
    print(f"\noverlapping rounds vs truncate: {a_o - a_p:+.3f} accuracy; "
          f"vs drop: {a_o - a_d:+.3f} — at the same deadline budget, "
          f"resumed chains lose no walk tails")

    # --- recorded trace: save, reload, replay bit-exactly -----------------
    overlap.trace.header.update(scenario=setup.name, build_seed=SEED,
                                key_seed=SEED, eval_every=6,
                                build_overrides={"policy": "overlap",
                                                 "rounds": ROUNDS})
    overlap.trace.save(TRACE_PATH)
    replayed = build_scenario("overlap_async", n=N, seed=SEED, rounds=ROUNDS,
                              policy="overlap").runner().replay(
        SimTrace.load(TRACE_PATH), jax.random.PRNGKey(SEED),
        setup.x_test, setup.y_test, eval_every=6)
    assert np.array_equal(np.asarray(overlap.state.device_params),
                          np.asarray(replayed.state.device_params))
    assert replayed.history.test_accuracy == overlap.history.test_accuracy
    print(f"\nrecorded {len(overlap.trace.windows)} windows -> {TRACE_PATH} "
          f"(schema v{overlap.trace.header['version']}); replayed "
          f"bit-identically through the flat engine. CLI equivalent:\n"
          f"  python -m repro.launch.sim --replay {TRACE_PATH}")

    # --- telemetry stream: save + render the standard run report ----------
    runner.obs.save(OBS_PATH, provenance=provenance(),
                    workload="example", scenario=setup.name, policy="overlap")
    print(f"\nobs stream -> {OBS_PATH}\n")
    print(render_report(ObsStream.load(OBS_PATH)))


if __name__ == "__main__":
    main()
