"""Virtual-time asynchronous DFedRW: partial updates vs dropping stragglers.

Runs the `straggler_tail` scenario (lognormal heavy-tailed device rates
under a wall-clock aggregation deadline) twice at identical protocol seeds
and timing draws — once aggregating each chain's completed prefix (the
paper's Eq. 11/14 partial updates) and once discarding unfinished chains
(the FedAvg-style baseline) — then a churn run where devices drop offline
mid-walk. Prints per-eval accuracy with the virtual-time column.

Usage:  PYTHONPATH=src python examples/async_straggler_sim.py
"""
import jax

from repro.sim import build_scenario

N, SEED, ROUNDS = 20, 0, 24


def run(name: str, **overrides):
    setup = build_scenario(name, n=N, seed=SEED, rounds=ROUNDS, **overrides)
    runner = setup.runner()
    label = f"{name}/{setup.sim.policy}"
    print(f"\n== {label}: deadline={setup.sim.deadline_s}s "
          f"bits={setup.cfg.quant.bits}")

    def cb(r, metrics, evald, record):
        print(f"  round {record.round:3d}  t={record.t_end:7.1f}s  "
              f"acc={evald['accuracy']:.3f}  "
              f"truncated={record.truncated_chains} "
              f"dropped={record.dropped_chains} "
              f"killed={int(record.killed.sum())}")

    result = runner.run(setup.rounds, jax.random.PRNGKey(SEED),
                        setup.x_test, setup.y_test, eval_every=6, callback=cb)
    final = result.final()
    print(f"  final acc={final['accuracy']:.3f} "
          f"virtual_time={final['virtual_time_s']:.0f}s "
          f"events={final['events_total']}")
    return final


def main() -> None:
    partial = run("straggler_tail", policy="partial")
    drop = run("straggler_tail", policy="drop")
    print(f"\npartial-update aggregation beats drop-stragglers by "
          f"{partial['accuracy'] - drop['accuracy']:+.3f} accuracy "
          f"at the same virtual deadline budget")
    run("churn_dropout")


if __name__ == "__main__":
    main()
