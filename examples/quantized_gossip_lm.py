"""Pod-scale DFedRW end-to-end: train a small LM with per-group divergent
params, random-walk batch reassignment and (quantized) gossip aggregation
over a simulated 8-device mesh -- numerically, not just lowering.

  python examples/quantized_gossip_lm.py        (sets its own XLA device count)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.dist.gossip import GossipConfig
from repro.dist.sharding import batch_specs, named
from repro.dist.steps import make_fed_train_step
from repro.models import transformer as T
from repro.models.config import ArchConfig


def main():
    cfg = ArchConfig(name="tiny-lm", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab=256)
    mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"))
    g = 4  # federated groups == pod axis
    for quant_bits, tag in [(32, "DFedRW"), (8, "QDFedRW-8b")]:
        gossip = GossipConfig(axis="pod", topology="ring", every=2,
                              quant_bits=quant_bits)
        step_fn, p_specs, fed_abs = make_fed_train_step(cfg, mesh, gossip,
                                                        lr_r=2.0, remat=False)
        key = jax.random.PRNGKey(0)
        base = T.init_params(cfg, key, jnp.float32)
        params = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (g, *l.shape)).copy(), base)
        params = jax.device_put(params, named(p_specs, mesh))
        vel = jax.tree_util.tree_map(jnp.zeros_like, params)
        jitted = jax.jit(step_fn)
        rng = np.random.default_rng(0)
        b, s = 16, 32
        with mesh:
            for step in range(40):
                # structured synthetic data: next = (3*tok + 7) % vocab
                t0 = rng.integers(0, cfg.vocab, size=(g, b, 1))
                seq = [t0]
                for _ in range(s):
                    seq.append((3 * seq[-1] + 7) % cfg.vocab)
                toks = np.concatenate(seq, axis=-1)
                batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                         "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
                bs = batch_specs(batch, mesh, fed_axis="pod")
                batch = jax.device_put(batch, named(bs, mesh))
                key, sub = jax.random.split(key)
                params, vel, loss = jitted(params, vel, batch, jnp.int32(step), sub)
                if (step + 1) % 10 == 0:
                    print(f"  [{tag}] step {step+1:3d} loss={float(loss):.4f}")
        # Group divergence after gossip: should be small (aggregated).
        leaf = jax.tree_util.tree_leaves(params)[0]
        spread = float(jnp.max(jnp.std(leaf.astype(jnp.float32), axis=0)))
        print(f"  [{tag}] final loss={float(loss):.4f} inter-group param spread={spread:.5f}\n")


if __name__ == "__main__":
    main()
