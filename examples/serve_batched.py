"""Continuous-batching serving demo: drives the `repro.serve` engine API
in-process across three smoke architectures (dense GQA, pure SSM, hybrid
MoE), with staggered arrivals and mixed request lengths — requests are
admitted as slots free up and retired on their own stop conditions, all
inside two compiled programs per arch.

Each engine carries an active-time ``repro.obs`` recorder (compile pauses
excluded): all three archs share one stream, which is saved and rendered
through the standard run report at the end — per-step-kind time, request
counts, TTFT/TPOT tails. See docs/OBSERVABILITY.md.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import tempfile

import numpy as np

ARCHS = ["qwen2-72b", "mamba2-130m", "jamba-1.5-large-398b"]
OBS_PATH = os.path.join(tempfile.gettempdir(), "serve_batched_obs.jsonl")


def run_arch(arch: str, rec) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(7)

    # Mixed-length workload with staggered arrivals: a burst at step 0,
    # then a trickle while the first wave is still decoding.
    reqs = []
    for i in range(12):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=(int(rng.integers(4, 24)),)),
            max_tokens=int(rng.integers(6, 24)),
            eos_id=-1,
            temperature=0.0,
            arrival_step=0 if i < 4 else int(rng.integers(2, 30)),
        ))

    eng = ServeEngine(cfg, params,
                      EngineConfig(max_concurrency=4, max_len=64, chunk=8),
                      obs=rec)
    results = eng.run(reqs)
    s = eng.metrics.summary()
    print(f"\n=== {cfg.name} ===")
    for st in results:
        m = eng.metrics.requests[st.request.rid]
        print(f"  req {st.request.rid:2d} arrived@{st.request.arrival_step:3d} "
              f"prompt={m.prompt_len:2d} gen={m.n_generated:2d} stop={st.stop} "
              f"tokens={st.generated[:6]}...")
    print(f"  {s['requests_finished']} requests | {s['tok_s']:.1f} gen tok/s | "
          f"{s['prefill_chunks']} prefill chunks + {s['decode_steps']} decode steps "
          f"| traces: {eng.trace_counts}")
    assert s["requests_finished"] == len(reqs)
    assert eng.trace_counts == {"prefill": 1, "decode": 1}, eng.trace_counts


if __name__ == "__main__":
    from repro.obs import (
        ObsStream, PausableWallClock, Recorder, provenance, render_report,
    )

    recorder = Recorder(clock=PausableWallClock())
    for arch in ARCHS:
        run_arch(arch, recorder)
    recorder.save(OBS_PATH, provenance=provenance(),
                  workload="example", archs=",".join(ARCHS))
    print(f"\nobs stream -> {OBS_PATH}\n")
    print(render_report(ObsStream.load(OBS_PATH)))
