"""End-to-end serving driver: batched autoregressive generation with the
KV-cache serving path, over any assigned architecture's smoke config.

  PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys

if __name__ == "__main__":
    for arch in ["qwen2-72b", "mamba2-130m", "jamba-1.5-large-398b"]:
        print(f"\n=== {arch} (smoke config) ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--batch", "8", "--prompt-len", "16", "--gen", "24"],
            check=True,
        )
